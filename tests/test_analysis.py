"""Tests for the ``repro.analysis`` static-analysis subsystem:
positive/negative fixtures per check, the suppression protocol, the
collective census, pytree round-trips, and the tier-1 comm-schedule
smoke (an extra psum or a broken s-step schedule fails here locally,
before CI)."""
import ast
import textwrap
from functools import partial

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

import analysis_fixtures as fx
from repro.analysis import lint, pallas_check, registry, run_all
from repro.analysis.findings import ERROR, Finding, apply_suppressions
from repro.analysis import comm_check
from repro.compat import make_mesh_auto, shard_map
from repro.core.kernels import (ExactGramOperator, KernelConfig,
                                LowRankGramOperator)
from repro.core.nystrom import NystromMap
from repro.core.perf_model import setup_collectives
from repro.launch.jaxpr_analysis import (COLLECTIVE_PRIMS,
                                         collective_census,
                                         count_collective_executions)


def _pallas_findings(fixture):
    with registry.capture() as calls:
        fixture()
    return pallas_check.analyze_calls(calls)


# ------------------------------------------------ pallas sanitizer -----

@pytest.mark.parametrize("bad,good,check", [
    (fx.racing_out_spec, fx.accumulating_out_spec, "CHK-RACE"),
    (fx.coverage_hole, fx.full_coverage, "CHK-HOLE"),
    (fx.misaligned_block, fx.aligned_block, "CHK-ALIGN"),
    (fx.vmem_hog, fx.vmem_modest, "CHK-VMEM"),
], ids=["race", "hole", "align", "vmem"])
def test_pallas_positive_negative(bad, good, check):
    caught = _pallas_findings(bad)
    assert check in {f.check for f in caught}, caught
    assert {f.check for f in caught} <= {check}, \
        "fixture should trip exactly one check kind"
    assert _pallas_findings(good) == []


def test_real_kernels_all_captured_and_clean():
    calls = registry.capture_entry_points()
    covered = {c.site for c in calls}
    sites = set(registry.discover_sites())
    assert sites and sites <= covered, sites - covered
    assert pallas_check.run() == []


# ----------------------------------------------------- suppressions -----

def test_noqa_suppresses_with_justification():
    f = Finding("CHK-X", ERROR, "mem.py", 2, "boom")
    out = apply_suppressions(
        [f], {"mem.py": ["# repro: noqa[CHK-X] known benign", "code()"]})
    assert out[0].suppressed and out[0].justification == "known benign"


def test_noqa_without_justification_is_a_finding():
    f = Finding("CHK-X", ERROR, "mem.py", 2, "boom")
    out = apply_suppressions(
        [f], {"mem.py": ["# repro: noqa[CHK-X]", "code()"]})
    assert out[0].check == "CHK-NOQA" and not out[0].suppressed


def test_noqa_other_id_does_not_suppress():
    f = Finding("CHK-X", ERROR, "mem.py", 2, "boom")
    out = apply_suppressions(
        [f], {"mem.py": ["# repro: noqa[CHK-Y] wrong check", "code()"]})
    assert not out[0].suppressed and out[0].check == "CHK-X"


# -------------------------------------------------------- jit lint -----

def test_tracer_branch_caught():
    src = textwrap.dedent("""
        def make_foo_round_fn(A):
            def round_fn(alpha, xs):
                if alpha > 0:
                    alpha = -alpha
                return float(alpha)
            return round_fn
    """)
    found = lint._check_tracer("<fx>", ast.parse(src))
    assert len(found) == 2
    assert {f.check for f in found} == {"CHK-TRACER"}


def test_tracer_static_tests_allowed():
    src = textwrap.dedent("""
        def make_foo_round_fn(A, gram_fn=None):
            def round_fn(alpha, xs):
                if gram_fn is not None:
                    alpha = gram_fn(alpha)
                if A.ndim == 2 and len(xs) > 1:
                    alpha = alpha + 1
                return alpha
            return round_fn
    """)
    assert lint._check_tracer("<fx>", ast.parse(src)) == []


def test_static_callable_argname_caught():
    src = textwrap.dedent("""
        @functools.partial(jax.jit, static_argnames=("gram_fn",))
        def solve(A, gram_fn: Optional[Callable] = None):
            return A
    """)
    found = lint._check_static("<fx>", ast.parse(src))
    assert [f.check for f in found] == ["CHK-STATIC"]


def test_static_non_callable_argname_clean():
    src = textwrap.dedent("""
        @functools.partial(jax.jit, static_argnames=("cfg",))
        def solve(A, cfg: KernelConfig = None):
            return A
    """)
    assert lint._check_static("<fx>", ast.parse(src)) == []


def test_lint_flags_known_host_records_only():
    found = lint.run()
    pytree = {f.message.split()[1] for f in found
              if f.check == "CHK-PYTREE"}
    # the host-side result records are flagged (and suppressed in-tree);
    # the registered operator containers must NOT appear
    assert "FitResult" in pytree
    assert pytree.isdisjoint({"ExactGramOperator", "LowRankGramOperator",
                              "NystromMap"})
    assert not any(f.check == "CHK-TRACER" for f in found)


@pytest.mark.parametrize("make", [
    lambda: ExactGramOperator(jnp.arange(6.0).reshape(3, 2),
                              KernelConfig("rbf")),
    lambda: LowRankGramOperator(jnp.arange(12.0).reshape(4, 3)),
    lambda: LowRankGramOperator(
        jnp.arange(12.0).reshape(4, 3),
        fmap=NystromMap(jnp.ones((3, 2)), jnp.eye(3))),
    lambda: NystromMap(jnp.ones((3, 2)), jnp.eye(3),
                       KernelConfig("linear")),
], ids=["exact", "lowrank", "lowrank+fmap", "nystrom"])
def test_registered_pytree_roundtrip(make):
    obj = make()
    leaves, treedef = jax.tree_util.tree_flatten(obj)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(back) is type(obj)
    for a, b in zip(leaves, jax.tree_util.tree_leaves(back)):
        assert jnp.array_equal(a, b)


# ------------------------------------------------ collective census -----

class _Prim:
    def __init__(self, name):
        self.name = name


class _Eqn:
    def __init__(self, name, params=None):
        self.primitive = _Prim(name)
        self.params = params or {}


class _Jaxpr:
    def __init__(self, eqns):
        self.eqns = eqns


@pytest.mark.parametrize("prim", sorted(COLLECTIVE_PRIMS))
def test_every_collective_prim_counted(prim):
    inner = _Jaxpr([_Eqn(prim, {"axes": ("model",)})])
    assert collective_census(inner) == ((prim, ("model",), 1),)
    # under a length-3 scan the site executes 3 times
    outer = _Jaxpr([_Eqn("scan", {"length": 3, "jaxpr": inner})])
    assert collective_census(outer) == ((prim, ("model",), 3),)
    assert count_collective_executions(outer) == 3


def test_census_counts_real_psum_under_scan():
    mesh = make_mesh_auto((1,), ("model",))

    @partial(shard_map, mesh=mesh, in_specs=(P("model"),),
             out_specs=P("model"), check_vma=False)
    def f(x):
        def body(c, _):
            return c + jax.lax.psum(jnp.sum(x), "model"), None
        c, _ = jax.lax.scan(body, 0.0, None, length=7)
        return x + c

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    census = collective_census(jaxpr)
    assert count_collective_executions(jaxpr) == 7
    assert all(u.axes == ("model",) for u in census)


# ----------------------------------------------- comm-schedule smoke -----

def test_comm_audit_full_matrix_clean():
    """The acceptance invariant: for all four solvers x {1d, 2d} x
    {linear, rbf}, traced collective executions match the modeled
    schedule and s-step communicates exactly 1/s as often."""
    assert comm_check.audit() == []


@pytest.mark.parametrize("problem,layout", sorted(comm_check.SOLVERS))
def test_sstep_executions_are_classical_over_s(problem, layout):
    for kernel in comm_check.KERNEL_NAMES:
        setup = setup_collectives(layout, kernel)
        cl = comm_check.expected_executions(
            comm_check.CommCase(problem, layout, "classical", kernel))
        ss = comm_check.expected_executions(
            comm_check.CommCase(problem, layout, "sstep", kernel))
        assert (cl - setup) == comm_check.S * (ss - setup)


def test_extra_psum_fails_the_count():
    """Positive fixture: a schedule with one extra collective per round
    must trip CHK-COMM when audited against the model."""
    case = comm_check.CommCase("krr", "1d", "sstep", "linear")
    census = comm_check.trace_case(case)
    doubled = tuple(u._replace(executions=2 * u.executions)
                    for u in census)
    found = comm_check.audit_case(case, doubled)
    assert [f.check for f in found] == ["CHK-COMM"]
    assert comm_check.audit_case(case, census) == []


def test_unknown_axis_name_caught():
    case = comm_check.CommCase("ksvm", "1d", "classical", "linear")
    census = comm_check.trace_case(case)
    renamed = tuple(u._replace(axes=("ring",)) for u in census)
    found = comm_check.audit_case(case, renamed)
    assert "CHK-AXIS" in {f.check for f in found}


# ------------------------------------------------- CHK-CARRY (guard) ----

def test_guard_check_accepts_real_carries():
    """The real guarded families + the real health predicate: every
    carry leaf is covered, no findings."""
    from repro.analysis import guard_check
    assert guard_check.run() == []


def test_guard_check_flags_blind_predicate(monkeypatch):
    """A predicate that reads only the first carry leaf leaves the rest
    unguarded — CHK-CARRY must fire for each missed floating leaf, per
    family, anchored at the factory def line."""
    from repro.analysis import guard_check

    def half_blind(state):
        leaves = jax.tree_util.tree_leaves(state)
        return jnp.all(jnp.isfinite(leaves[0]))

    monkeypatch.setattr(guard_check, "finite_health", half_blind)
    found = guard_check.run()
    assert found and all(f.check == "CHK-CARRY" for f in found)
    assert all(f.severity == ERROR for f in found)
    assert len(found) == 4                    # one missed leaf x family
    assert all(f.line > 0 and f.path.endswith(".py") for f in found)


def test_guard_check_flags_rejecting_predicate(monkeypatch):
    """A predicate that rejects healthy carries freezes every guarded
    solve at round 0 — also a finding."""
    from repro.analysis import guard_check
    monkeypatch.setattr(guard_check, "finite_health",
                        lambda state: jnp.asarray(False))
    found = guard_check.run()
    assert len(found) == 4
    assert all("rejects a finite" in f.message for f in found)


# --------------------------------------------------------- tree gate -----

def test_tree_is_clean_under_full_analysis():
    findings = run_all()
    active = [f for f in findings if not f.suppressed]
    assert active == [], [f.format() for f in active]
    assert all(f.justification for f in findings if f.suppressed)
