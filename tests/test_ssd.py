"""Mamba-2 SSD matmul form vs the elementwise associative-scan reference,
including through the full zamba2 model and decode parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_params
from repro.models.mamba import init_mamba2, mamba2_forward


def _cfgs():
    base = get_config("zamba2_1p2b", reduced=True)
    return (dataclasses.replace(base, ssm_impl="scan"),
            dataclasses.replace(base, ssm_impl="ssd"))


@pytest.mark.parametrize("L", [8, 64, 100])   # below/at/above chunk=64
def test_ssd_matches_scan_block(L):
    scan_cfg, ssd_cfg = _cfgs()
    p = init_mamba2(jax.random.key(0), scan_cfg)
    x = 0.5 * jax.random.normal(jax.random.key(1),
                                (2, L, scan_cfg.d_model), jnp.float32)
    a = mamba2_forward(p, scan_cfg, x)
    b = mamba2_forward(p, ssd_cfg, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-4)


def test_ssd_full_model_matches_scan():
    scan_cfg, ssd_cfg = _cfgs()
    params = init_params(jax.random.key(0), scan_cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0,
                              scan_cfg.vocab_size)
    a = forward(params, scan_cfg, toks)
    b = forward(params, ssd_cfg, toks)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ssd_grads_finite():
    _, ssd_cfg = _cfgs()
    p = init_mamba2(jax.random.key(2), ssd_cfg)
    x = 0.5 * jax.random.normal(jax.random.key(3),
                                (2, 32, ssd_cfg.d_model), jnp.float32)

    def loss(p, x):
        return jnp.sum(mamba2_forward(p, ssd_cfg, x) ** 2)

    g = jax.grad(loss)(p, x)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
