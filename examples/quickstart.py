"""Quickstart: the paper's contribution in ~40 lines.

Solve kernel SVM with classical DCD and s-step DCD, confirm they produce
the same solution, and see the communication math that makes s-step win.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (KernelConfig, SVMConfig, coordinate_schedule,
                        dcd_ksvm, ksvm_duality_gap, sstep_dcd_ksvm)
from repro.core.perf_model import Machine, Problem, bdcd_cost, \
    sstep_bdcd_cost
from repro.data.synthetic import classification_dataset

# A small binary classification problem (duke-breast-cancer scale).
A, y = classification_dataset(jax.random.key(0), m=44, n=7129)
cfg = SVMConfig(C=1.0, loss="l1", kernel=KernelConfig("rbf", sigma=1.0))

H = 512                                   # coordinate-descent iterations
sched = coordinate_schedule(jax.random.key(1), H, A.shape[0])
alpha0 = jnp.zeros(A.shape[0])

# Classical DCD: one kernel column + one (distributed: all-reduce) / iter.
alpha_dcd, _ = dcd_ksvm(A, y, alpha0, sched, cfg)

# s-step DCD: one m x s kernel slab + ONE all-reduce per s iterations.
alpha_s, _ = sstep_dcd_ksvm(A, y, alpha0, sched, cfg, s=32)

dev = float(jnp.max(jnp.abs(alpha_dcd - alpha_s)))
gap = float(ksvm_duality_gap(A, y, alpha_s, cfg))
print(f"max |alpha_sstep - alpha_dcd| = {dev:.2e}   (same solution)")
print(f"duality gap after {H} iters  = {gap:.3e}")

# Why it wins at scale (Hockney model, paper Theorems 1-2):
prob = Problem(m=44, n=7129, b=1, H=H, kernel="rbf")
mach = Machine()
for P in (16, 128, 512):
    t1 = bdcd_cost(prob, mach, P)["time"]
    t32 = sstep_bdcd_cost(prob, mach, P, 32)["time"]
    print(f"P={P:4d}: classical {t1*1e3:7.2f} ms  "
          f"s=32 {t32*1e3:7.2f} ms  -> {t1/t32:.1f}x")
