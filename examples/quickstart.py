"""Quickstart: the paper's contribution through the public API, ~30 lines.

Solve kernel SVM with classical DCD and s-step DCD via ``repro.api``,
confirm they produce the same solution, and see the communication math
that makes s-step win.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.api import KernelSVM, SolverOptions
from repro.core.perf_model import Machine, Problem, bdcd_cost, \
    sstep_bdcd_cost
from repro.data.synthetic import classification_dataset

# A small binary classification problem (duke-breast-cancer scale).
A, y = classification_dataset(jax.random.key(0), m=44, n=7129)
H = 512                                   # coordinate-descent iterations

# Classical DCD: one kernel column + one (distributed: all-reduce) / iter.
clf_dcd = KernelSVM(C=1.0, loss="l1", kernel="rbf",
                    options=SolverOptions(method="classical", max_iters=H))
res_dcd = clf_dcd.fit(A, y)

# s-step DCD: one m x s kernel slab + ONE all-reduce per s iterations —
# same schedule (same seed), same solution.
clf_s = KernelSVM(C=1.0, loss="l1", kernel="rbf",
                  options=SolverOptions(method="sstep", s=32, max_iters=H,
                                        record=True))
res_s = clf_s.fit(A, y)

dev = float(jnp.max(jnp.abs(res_dcd.alpha - res_s.alpha)))
print(f"max |alpha_sstep - alpha_dcd| = {dev:.2e}   (same solution)")
print(f"duality gap after {H} iters  = {float(res_s.metric_history()[-1]):.3e}")
print(f"train accuracy = {float(jnp.mean(clf_s.predict(A) == y)):.3f}")
print(f"modeled comm: classical {res_dcd.comm['msgs']:.0f} msgs vs "
      f"s-step {res_s.comm['msgs']:.0f} msgs for the same words")

# Why it wins at scale (Hockney model, paper Theorems 1-2):
prob = Problem(m=44, n=7129, b=1, H=H, kernel="rbf")
mach = Machine()
for P in (16, 128, 512):
    t1 = bdcd_cost(prob, mach, P)["time"]
    t32 = sstep_bdcd_cost(prob, mach, P, 32)["time"]
    print(f"P={P:4d}: classical {t1*1e3:7.2f} ms  "
          f"s=32 {t32*1e3:7.2f} ms  -> {t1/t32:.1f}x")
