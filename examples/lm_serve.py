"""Serving example: batched greedy decoding with KV caches (and SSM states
for mamba/hybrid archs) using the public serve API.

    PYTHONPATH=src python examples/lm_serve.py --arch qwen3-1.7b
    PYTHONPATH=src python examples/lm_serve.py --arch falcon-mamba-7b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_decode_state, init_params, prefill_cross_kv
from repro.train import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = init_params(jax.random.key(0), cfg)
    B = args.batch
    max_seq = args.prompt_len + args.new_tokens + 1
    state = init_decode_state(cfg, B, max_seq,
                              with_encoder=bool(cfg.encoder_layers))
    if cfg.encoder_layers:
        audio = jax.random.normal(jax.random.key(1),
                                  (B, cfg.encoder_seq, cfg.d_model))
        state["cross_kv"] = prefill_cross_kv(params, cfg, audio)

    prompt = jax.random.randint(jax.random.key(2), (B, args.prompt_len),
                                0, cfg.vocab_size)
    out, state = greedy_generate(params, cfg, state, prompt,
                                 args.new_tokens,
                                 temperature=args.temperature)
    print(f"arch={cfg.name} cache_pos={state['pos'][0]}")
    for i in range(B):
        print(f"  req{i}: prompt={list(map(int, prompt[i]))} "
              f"-> {list(map(int, out[i]))}")
    assert out.shape == (B, args.new_tokens)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out < cfg.vocab_size))
    print("ok")


if __name__ == "__main__":
    main()
