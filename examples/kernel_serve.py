"""Serving walkthrough: two models, one device operator, live refit.

Fit a kernel SVM and a kernel ridge model on the SAME training data,
register both — the registry content-hashes the operator and folds
them into one group, so every engine block serves BOTH models in one
call — then stream mixed traffic through the continuous batcher and
absorb fresh labeled rows mid-stream with ``registry.refit`` (warm
start + atomic swap).  DESIGN.md §13.

    PYTHONPATH=src python examples/kernel_serve.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelRidge, KernelSVM, SolverOptions
from repro.core.predict import serve_cache_size
from repro.data.synthetic import classification_dataset
from repro.serve import ModelRegistry, ServingEngine

m, n = 256, 16
A, y = classification_dataset(jax.random.key(0), m=m, n=n)
opts = SolverOptions(method="sstep", s=8, max_iters=16384, tol=1e-7)

# -- two models, one training set -------------------------------------
svm = KernelSVM(C=1.0, kernel="rbf", options=opts)
svm.fit(A, y)
krr = KernelRidge(lam=1.0, kernel="rbf", options=opts)
krr.fit(A, y)                      # same data, same kernel -> same gram

# krr goes through the artifact layer (save -> load), svm stays live:
# both paths land in the same registry group because the operator
# CONTENT matches — one device-resident gram, (m, 2) stacked weights.
art_dir = tempfile.mkdtemp(prefix="kernel-serve-")
krr.save(art_dir)
reg = ModelRegistry(predict_batch=256)
reg.load("krr", art_dir)
reg.register("svm", svm)
assert reg.n_groups == 1 and reg.group("krr") is reg.group("svm")
print(f"2 models, {reg.n_groups} operator group "
      f"(weights stacked {reg.group('krr').W.shape})")

# -- continuous batching ----------------------------------------------
eng = ServingEngine(reg, slots=64, max_queue=128)
eng.warmup()                       # compile every pow-2 bucket ONCE
c0 = serve_cache_size()

Xq = np.asarray(A)                 # host query rows (engine batches on
tickets = []                       # host: one transfer per block)
for k in range(48):                # interleaved mixed-model traffic
    name = "svm" if k % 2 else "krr"
    tickets.append(eng.submit(name, Xq[k], deadline_s=1.0))
eng.run_until_idle()

for t in tickets:                  # engine block == direct group path
    ref = reg.predict(t.name, jnp.asarray(Xq[t.id][None, :]))
    assert float(jnp.max(jnp.abs(t.result - ref))) <= 1e-6
assert serve_cache_size() == c0, "admission must never compile"
print(f"served {eng.stats['served']} tickets in {eng.stats['blocks']} "
      f"mixed-model blocks, jit cache growth 0, "
      f"p50 {eng.latency_quantiles()['p50'] * 1e3:.2f} ms (virtual)")

# -- mid-stream refit -------------------------------------------------
# Fresh labeled traffic arrives for krr.  refit re-solves on the
# combined data warm-started from the serving alpha, then atomically
# swaps: the svm keeps the OLD shared operator (its training set did
# not change), krr moves to a new group over the grown data.
X_new, y_new = classification_dataset(jax.random.key(7), m=32, n=n)
before = reg.predict("krr", jnp.asarray(Xq[:8]))
res = reg.refit("krr", X_new, y_new)
reg.warmup()                       # compile the NEW group's buckets
after = reg.predict("krr", jnp.asarray(Xq[:8]))
print(f"refit: +{int(X_new.shape[0])} rows, {res.iters_run} warm iters, "
      f"{reg.n_groups} groups now, served values moved "
      f"{float(jnp.max(jnp.abs(after - before))):.2e}")

# the swap is equivalent to a cold fit on the combined data
cold = KernelRidge(lam=1.0, kernel="rbf", options=opts)
cold.fit(jnp.concatenate([A, X_new]), jnp.concatenate([y, y_new]))
drift = float(jnp.max(jnp.abs(after - cold.predict(jnp.asarray(Xq[:8])))))
assert drift <= 1e-5
print(f"refit vs cold fit on combined data: {drift:.2e} (<= 1e-5)")

# post-refit traffic still never compiles at admission
c1 = serve_cache_size()
for k in range(16):
    eng.submit("krr" if k % 2 else "svm", Xq[k])
eng.run_until_idle()
assert serve_cache_size() == c1
print("post-refit steady traffic: jit cache growth 0")
