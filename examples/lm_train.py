"""End-to-end driver (deliverable b): train a ~100M-param qwen3-family
model for a few hundred steps on the synthetic token pipeline, with
checkpoint/resume and loss reporting.

    PYTHONPATH=src python examples/lm_train.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/lm_train.py --tiny     # CI-speed
"""
import argparse
import dataclasses
import sys

from repro.configs import get_config
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    if args.tiny:
        argv = ["--arch", "qwen3-1.7b", "--reduced",
                "--steps", str(args.steps or 30),
                "--batch", "4", "--seq", "32", "--lr", "1e-2",
                "--log-every", "5"]
    else:
        # ~100M-param decoder (qwen3 family traits, scaled):
        # patch the registry entry on the fly via launch.train's --arch
        # reduced path is too small; use a custom injection instead.
        import repro.configs.qwen3_1p7b as q
        cfg100m = dataclasses.replace(
            q.CONFIG, n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=8192, remat="none")
        q.REDUCED = cfg100m      # launch.train --reduced picks this up
        argv = ["--arch", "qwen3-1.7b", "--reduced",
                "--steps", str(args.steps or 200),
                "--batch", "8", "--seq", "128", "--lr", "3e-3",
                "--ckpt-dir", "/tmp/repro_lm100m_ckpt", "--log-every", "10"]

    losses = train_main(argv)
    if losses[-1] >= losses[0]:
        sys.exit("loss did not decrease")
    print(f"loss decreased {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
