"""Kernel ridge regression end-to-end through ``repro.api``: s-step BDCD
on a synthetic abalone-scale dataset, optionally consuming features from
one of the assigned LM architectures (the honest intersection of the
paper and the LM zoo: a kernel readout on frozen backbone embeddings).

    PYTHONPATH=src python examples/krr_regression.py
    PYTHONPATH=src python examples/krr_regression.py --features-from qwen3-1.7b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import KernelRidge, SolverOptions
from repro.core import (KernelConfig, krr_closed_form,
                        relative_solution_error)
from repro.data.synthetic import regression_dataset


def lm_features(arch: str, tokens):
    """Frozen-backbone features: mean-pooled final hidden states of the
    REDUCED config (random init — a stand-in for a pretrained encoder)."""
    from repro.configs import get_config
    from repro.models import forward, init_params
    cfg = get_config(arch, reduced=True)
    params = init_params(jax.random.key(0), cfg)
    logits = forward(params, cfg, tokens)          # (B, S, V)
    # use pre-softmax logit statistics as features (cheap demo readout)
    feats = jnp.concatenate([logits.mean(1)[:, :64],
                             logits.max(1)[:, :64]], axis=-1)
    return feats / (jnp.linalg.norm(feats, axis=1, keepdims=True) + 1e-6)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--features-from", default=None)
    ap.add_argument("--m", type=int, default=512)
    ap.add_argument("--s", type=int, default=16)
    ap.add_argument("--b", type=int, default=32)
    ap.add_argument("--H", type=int, default=256)
    ap.add_argument("--tol", type=float, default=0.0)
    args = ap.parse_args()

    if args.features_from:
        key = jax.random.key(3)
        tokens = jax.random.randint(key, (args.m, 16), 0, 512)
        A = lm_features(args.features_from, tokens)
        w = jax.random.normal(jax.random.key(4), (A.shape[1],))
        y = jnp.tanh(A @ w)
        print(f"features from {args.features_from}: A={A.shape}")
    else:
        A, y = regression_dataset(jax.random.key(2), args.m, 8)

    kern = KernelConfig("rbf", sigma=1.0)

    def fit(method, s=1):
        opts = SolverOptions(method=method, s=s, b=args.b,
                             max_iters=args.H, tol=args.tol, seed=5)
        reg = KernelRidge(lam=0.5, kernel=kern, options=opts)
        return reg, reg.fit(A, y)

    _, r_bdcd = fit("classical")
    reg, r_s = fit("sstep", args.s)
    astar = krr_closed_form(A, y, reg.cfg)
    print(f"rel err: bdcd "
          f"{float(relative_solution_error(r_bdcd.alpha, astar)):.2e} | "
          f"s-step {float(relative_solution_error(r_s.alpha, astar)):.2e} | "
          f"agree {float(jnp.max(jnp.abs(r_bdcd.alpha - r_s.alpha))):.2e}")
    print(f"s-step: {r_s.rounds_run} comm rounds vs classical "
          f"{r_bdcd.rounds_run} — modeled comm {r_s.comm['time']*1e3:.2f} "
          f"vs {r_bdcd.comm['time']*1e3:.2f} ms (P=16 would diverge more)")
    pred = reg.predict(A)
    mse = float(jnp.mean((pred - y) ** 2))
    print(f"train MSE {mse:.4f} (var(y) = {float(jnp.var(y)):.4f})")


if __name__ == "__main__":
    main()
